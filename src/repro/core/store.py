"""Materialized-model store: descriptors + sufficient statistics + persistence.

Storage cost is the paper's explicit trade-off (Table 1) — the store tracks
bytes per family and supports an LRU byte budget.  Persistence is a plain
``npz`` per entry plus a JSON manifest so a store survives process restarts
(and, at cluster scale, host replacement: the manifest carries content
hashes for integrity).  The npz-plus-manifest machinery lives on the shared
:class:`PinnedStore` base — subclasses supply entry (de)serialization hooks
— so the analytical ``ModelStore`` and the serving ``SegmentStore`` share
one durable materialization layer: one manifest schema, one atomicity
discipline (write to a temp directory, rename into place), and one
retention-metadata round-trip (hits / last-touch, pins excluded) so the
cost-model eviction policy resumes with honest scores after a restart.

The base is also *tier-aware*: entries may be resident somewhere other
than device memory (host RAM, spill files on disk), and the byte-pressure
loop asks subclass hooks which entries count against the budget
(``_pressure_nbytes``/``_evictable``) and how to relieve pressure by one
entry (``_relegate`` — evict by default; the serving store demotes down
the tier ladder when the cost model says the bytes are worth keeping).
Serialization can run off-thread on a :class:`BackgroundWriter`
(``save_async``), and long-lived snapshot directories can be rewritten by
``compact_snapshot`` to break hard-link chains and drop stranded files.
"""
from __future__ import annotations

import copy
import hashlib
import json
import os
import queue
import shutil
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Iterable, Iterator, Optional

import numpy as np

from .cost import CostModel
from .descriptors import DescriptorIndex, Range
from .suffstats import STATS_FAMILIES, Combinable

#: eviction policies understood by :class:`PinnedStore`
EVICTION_POLICIES = ("cost", "lru")

#: residency ladder, fastest first
RESIDENCY_TIERS = ("device", "host", "disk")

#: tier policies understood by the serving store ("tiered" demotes down the
#: ladder when the cost model prefers it; "evict" restores binary drop)
TIER_POLICIES = ("tiered", "evict")

#: manifest filename shared by every persistent store
MANIFEST_NAME = "MANIFEST.json"

#: manifest schema version ("models" lists of version 1 became "entries";
#: version 3 added per-entry payload precision — int8 entries carry a
#: "precision"/"quant" record and qscale_* arrays, and their npz files
#: are deflate-compressed)
MANIFEST_VERSION = 3

#: manifest versions :meth:`PinnedStore.load` accepts.  Version 2
#: snapshots predate segment precision: their records simply lack the
#: "precision" key and every consumer defaults it to "fp32", so they
#: reload unchanged.
COMPAT_MANIFEST_VERSIONS = (2, 3)


def flatten_tree(tree):
    """Flatten a nested dict/list/tuple-of-arrays cache tree for npz storage.

    Returns ``(spec, leaves)`` where ``spec`` is a JSON-serializable
    description of the container structure (leaf slots reference positions
    in ``leaves``).  Unlike ``jax.tree_util`` treedefs, the spec survives a
    round-trip through a text manifest, which is what lets a KV segment's
    arbitrary cache pytree reload in a fresh process.
    """
    leaves: list[np.ndarray] = []

    def go(node):
        if isinstance(node, dict):
            return {"t": "dict", "items": [[k, go(v)] for k, v in node.items()]}
        if isinstance(node, (list, tuple)):
            kind = "tuple" if isinstance(node, tuple) else "list"
            return {"t": kind, "items": [go(v) for v in node]}
        if node is None:
            return {"t": "none"}
        leaves.append(np.asarray(node))
        return {"t": "leaf", "i": len(leaves) - 1}

    return go(tree), leaves


def unflatten_tree(spec, leaves, *, leaf_fn=None):
    """Inverse of :func:`flatten_tree`; ``leaf_fn`` maps each loaded array
    (e.g. ``jnp.asarray`` to move segments onto the device at load time)."""

    def go(node):
        t = node["t"]
        if t == "dict":
            return {k: go(v) for k, v in node["items"]}
        if t in ("list", "tuple"):
            out = [go(v) for v in node["items"]]
            return tuple(out) if t == "tuple" else out
        if t == "none":
            return None
        leaf = leaves[node["i"]]
        return leaf_fn(leaf) if leaf_fn is not None else leaf

    return go(spec)


def _link_or_copy(src: Path | str, dst: Path | str) -> None:
    """Hard-link ``src`` to ``dst``, falling back to a metadata-preserving
    copy on filesystems that refuse links (``EXDEV`` across devices,
    ``EPERM`` on link-less mounts).  Raises ``OSError`` only when both
    fail."""
    try:
        os.link(src, dst)
    except OSError:
        shutil.copy2(src, dst)


class BackgroundWriter:
    """Single-worker, bounded-queue executor for store I/O.

    Keeps serialization, hashing, and file shuffling off the serving
    thread: spill writes and snapshot saves enqueue a closure and return
    immediately.  One worker means writes are totally ordered (a spill
    enqueued before a snapshot lands first, so the snapshot can hard-link
    it), and the bounded queue gives backpressure — :meth:`submit` returns
    ``False`` instead of blocking when the queue is full, and callers
    decide whether to drop the job (snapshots coalesce) or do the work
    inline (spills must land).  The worker is a daemon thread, so a hung
    filesystem can never wedge process exit.
    """

    def __init__(self, maxsize: int = 8) -> None:
        self._q: queue.Queue = queue.Queue(maxsize)
        self._thread: Optional[threading.Thread] = None
        self.jobs_done = 0
        self.jobs_failed = 0

    def submit(self, fn) -> bool:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="store-writer", daemon=True)
            self._thread.start()
        try:
            self._q.put_nowait(fn)
        except queue.Full:
            return False
        return True

    def depth(self) -> int:
        """Jobs queued or running (0 when idle)."""
        return int(self._q.unfinished_tasks)

    def drain(self) -> None:
        """Block until every submitted job has finished."""
        self._q.join()

    def _run(self) -> None:
        while True:
            fn = self._q.get()
            try:
                fn()
            except BaseException:
                self.jobs_failed += 1
            else:
                self.jobs_done += 1
            finally:
                self._q.task_done()


@dataclass
class _SaveItem:
    """One entry of a snapshot, frozen on the serving thread.

    ``entry`` is a shallow copy — it pins the payload reference current at
    capture time, so the background worker serializes a consistent view
    even if the live entry is demoted, promoted, or dropped mid-write.
    ``source`` is a ``(path, record)`` pair when the entry's npz bytes
    already exist on disk (previous snapshot or spill file) and can be
    hard-linked instead of re-serialized.
    """

    key: str
    entry: Any
    source: Optional[tuple[Path, dict]]
    manifest: dict
    retention: dict


@dataclass
class StoredModel:
    model_id: str
    family: str
    rng: Range
    stats: Combinable
    created_s: float = field(default_factory=time.time)
    last_used_s: float = field(default_factory=time.time)
    hits: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return self.stats.nbytes


class PinnedStore:
    """Pin-aware, cost-model-weighted eviction shared by byte-budgeted stores.

    Used by :class:`ModelStore` (materialized statistics) and the serving
    ``SegmentStore`` (KV segments): both materialize new entries *during*
    plan execution, so a put-triggered eviction must never reclaim an entry
    a still-running plan references (put-during-execute).  Pins are
    reentrant counts; the eviction loop lives here so policy changes apply
    to every store.  Subclasses provide ``byte_budget``/``nbytes()``/
    ``evictions`` plus the ``_entries()`` / ``_evict(victim)`` hooks.

    Victim selection (``policy="cost"``, the default) is *benefit per
    byte*, not recency: each entry's retention score is

        ``recompute_s(entry) · decayed_frequency(entry) / nbytes(entry)``

    where ``recompute_s`` is the unified cost model's F(n) over the
    entry's descriptor (what a future request pays to rebuild it from
    base data / re-prefill it), ``decayed_frequency`` is ``1 + hits``
    decayed exponentially by idle time (half-life
    ``decay_half_life_s``), and ``nbytes`` is the budget the entry
    occupies.  The cheapest-to-rebuild byte goes first; frequently hit
    entries survive a flood of never-reused newcomers (scan resistance
    global LRU lacks).  Exact score ties fall back to least recently
    used, so homogeneous workloads behave exactly as before.

    ``policy="lru"`` restores the pre-cost behaviour — kept so benchmarks
    can hold the byte budget fixed and compare policies.  The default may
    also be overridden process-wide with ``REPRO_EVICTION_POLICY``.
    """

    def __init__(self, *, cost_model: Optional[CostModel] = None,
                 policy: Optional[str] = None,
                 decay_half_life_s: float = 300.0,
                 writer: Optional[BackgroundWriter] = None) -> None:
        self._pins: dict[str, int] = {}
        self.cost = cost_model if cost_model is not None else CostModel()
        if policy is None:
            policy = os.environ.get("REPRO_EVICTION_POLICY", "cost")
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"expected one of {EVICTION_POLICIES}")
        self.policy = policy
        self.decay_half_life_s = decay_half_life_s
        # incremental-snapshot state: entry key -> manifest record of the
        # entry's *immutable* (array-backed) part as last written/loaded,
        # including the npz filename and checksum.  Entry payloads are
        # frozen at put time, so a key present here means the previous
        # snapshot's file can be reused verbatim (hard-linked) instead of
        # re-serialized — see save().
        self._entry_records: dict[str, dict] = {}
        self._snapshot_dir: Optional[Path] = None
        #: {"written": n, "reused": m} for the most recent save()
        self.last_save: dict[str, int] = {}
        # background-save state.  _records_dirty guards the one race an
        # off-thread save opens: a put() that replaces an entry after the
        # save captured must not have its stale record re-installed when
        # the write lands.
        self._writer = writer
        self._records_lock = threading.Lock()
        self._records_dirty: set[str] = set()
        self._save_pending = False
        self._load_src: Optional[Path] = None
        self.bg_saves = 0
        self.bg_save_drops = 0
        self.save_errors: list[BaseException] = []
        #: seconds the calling thread spent blocked waiting on the writer
        self.save_stall_s = 0.0
        #: entry files ignored+removed by load() (stranded by a crashed
        #: compaction or an interrupted foreign save)
        self.swept_stranded = 0

    @property
    def writer(self) -> Optional[BackgroundWriter]:
        return self._writer

    def _ensure_writer(self) -> BackgroundWriter:
        if self._writer is None:
            self._writer = BackgroundWriter()
        return self._writer

    def pin(self, ids: Iterable[str]) -> tuple:
        """Acquire reentrant pins on ``ids``; returns the token for
        :meth:`unpin`.

        The non-lexical form of :meth:`pinned`, for holders whose lifetime
        is an object rather than a block — an async prefill ticket pins the
        segments its dispatched build references at submit time and releases
        them only when the build's store insertions are finalized, so
        eviction can never reclaim an entry an un-joined build still reads.
        ``None`` ids (gap plan steps) are skipped.
        """
        token = tuple(i for i in ids if i is not None)
        for i in token:
            self._pins[i] = self._pins.get(i, 0) + 1
        return token

    def unpin(self, token: Iterable[str]) -> None:
        """Release pins taken by :meth:`pin` and re-enforce the byte budget
        (puts while pinned may have left the store over budget with nothing
        evictable)."""
        for i in token:
            n = self._pins.get(i, 0) - 1
            if n > 0:
                self._pins[i] = n
            else:
                self._pins.pop(i, None)
        self._maybe_evict()

    @contextmanager
    def pinned(self, ids: Iterable[str]):
        """Hold the given entries in the store for the duration of the block."""
        token = self.pin(ids)
        try:
            yield
        finally:
            self.unpin(token)

    def _entries(self) -> dict:
        raise NotImplementedError

    def _evict(self, victim) -> None:
        raise NotImplementedError

    def _recompute_s(self, entry) -> float:
        """Estimated seconds to rebuild ``entry`` from base data if it is
        evicted and later needed — the unified cost model's F over the
        entry's descriptor.  Subclasses may refine (e.g. price a KV
        segment's prefill differently from a statistics scan)."""
        return self.cost.recompute_s(entry.rng.size)

    def _expected_reuses(self, entry) -> float:
        """Prior on how often ``entry`` will be hit again — the cost model's
        static ``expected_reuses`` (1.0 by default, reproducing the classic
        ``1 + hits`` frequency term).  ``SegmentStore`` overrides this with
        the *observed* per-document reuse rate so retention scores learn
        which tenants actually come back."""
        return self.cost.expected_reuses

    def retention_score(self, entry, now: Optional[float] = None) -> float:
        """Benefit-per-byte of keeping ``entry`` resident (higher = keep).

        ``recompute_s · (prior + hits) · 2^(−idle/half_life) / nbytes``:
        the expected seconds of rebuild work one stored byte saves, with
        the hit count (plus the reuse prior, see ``_expected_reuses``)
        standing in for reuse probability and decayed by idle time so dead
        entries eventually lose to fresh ones.  ``nbytes`` is what the
        entry actually occupies — for bucket-padded KV segments that is
        the padded capacity, not the valid length, so victim ranking
        prices real residency.
        """
        now = time.time() if now is None else now
        idle = max(now - entry.last_used_s, 0.0)
        freq = (self._expected_reuses(entry) + entry.hits) \
            * 2.0 ** (-idle / self.decay_half_life_s)
        return self._recompute_s(entry) * freq / max(entry.nbytes, 1)

    def _pick_victim(self, candidates: list):
        if self.policy == "lru":
            return min(candidates, key=lambda e: e.last_used_s)
        now = time.time()
        # score ties (identical entries, quantized clocks) degrade to LRU
        return min(candidates,
                   key=lambda e: (self.retention_score(e, now), e.last_used_s))

    # -- residency hooks ----------------------------------------------------
    # The pressure loop is tier-aware: subclasses decide which bytes count
    # against the budget, which entries are fair game, and how to relieve
    # pressure by one entry.  The base defaults reproduce plain
    # evict-under-budget exactly.

    def _pressure_nbytes(self) -> int:
        """Bytes counted against ``byte_budget``.  The base counts every
        entry; the serving store counts only the device tier (host and
        disk residents are precisely the bytes the budget pushed out)."""
        return self.nbytes()

    def _evictable(self, entry) -> bool:
        """Whether ``entry`` may be selected by the pressure loop (pins are
        checked separately).  The serving store limits victims to the
        device tier; lower tiers answer to ``_enforce_tiers``."""
        return True

    def _relegate(self, victim) -> bool:
        """Relieve byte pressure by one entry; return ``False`` to stop the
        loop (nothing left that is safe to reclaim).  The base evicts; the
        serving store may instead demote the victim down the residency
        ladder when the cost model prices the round-trip below a rebuild."""
        if len(self._entries()) <= 1:
            return False
        self._evict(victim)
        self.evictions += 1
        return True

    def _enforce_tiers(self) -> None:
        """Enforce lower-tier capacity limits after the device-pressure
        loop (e.g. a host-RAM budget cascading into disk spill)."""

    def _maybe_evict(self) -> None:
        if self.byte_budget is not None:
            while self._pressure_nbytes() > self.byte_budget:
                candidates = [e for k, e in self._entries().items()
                              if k not in self._pins and self._evictable(e)]
                if not candidates:
                    break  # everything under pressure is pinned
                if not self._relegate(self._pick_victim(candidates)):
                    break
        self._enforce_tiers()

    # -- persistence (shared npz + manifest machinery) ----------------------
    # Subclasses implement the two entry hooks; the base owns the manifest
    # schema, checksums, atomicity, and the retention-metadata round-trip.

    def _serialize_entry(self, entry) -> tuple[dict, dict]:
        """``entry -> (arrays, record)``: npz payload + JSON manifest record.

        The record must cover only state that is *frozen* once the entry is
        stored (descriptor, tree spec, array-derived fields) — it is cached
        and reused verbatim by incremental saves.  Fields that keep mutating
        after the put (alias sets, cross-session hit counts, per-model meta)
        belong in :meth:`_entry_manifest`, which is re-evaluated on every
        save.
        """
        raise NotImplementedError

    def _entry_manifest(self, entry) -> dict:
        """Manifest-only fields that may mutate after the entry's arrays are
        frozen; merged into the (possibly cached) record at every save."""
        return {}

    def _deserialize_entry(self, record: dict, arrays) -> str:
        """Re-insert one manifest record; returns the entry's store key."""
        raise NotImplementedError

    def _store_meta(self) -> dict:
        """Store-level state carried in the manifest (e.g. bucket size)."""
        return {}

    def _apply_store_meta(self, meta: dict) -> None:
        """Adopt store-level manifest state *before* entries deserialize."""

    def _finish_load(self, meta: dict) -> None:
        """Post-load fixups; the base re-enforces the byte budget (a store
        snapshotted under a looser budget sheds down to the current one)."""
        self._maybe_evict()

    def _invalidate_record(self, key: str) -> None:
        """Drop the cached snapshot record for ``key`` (its payload was
        replaced).  Also marks the key dirty so an in-flight background
        save cannot re-install a stale record over the invalidation."""
        with self._records_lock:
            self._entry_records.pop(key, None)
            self._records_dirty.add(key)

    def _entry_file_source(self, key: str, entry) -> Optional[tuple[Path, dict]]:
        """``(path, record)`` for an entry whose exact npz bytes already
        exist on disk, or ``None`` if it must be serialized from scratch.

        Entry payloads are immutable once stored, so if ``key`` was part of
        the last snapshot this store wrote (or loaded), its file can be
        hard-linked into the new snapshot as-is — no device sync to fetch
        the arrays, no serialization, no re-hash.  The serving store also
        answers with disk-tier spill files here, making snapshots of
        spilled segments link-cheap too.
        """
        with self._records_lock:
            cached = self._entry_records.get(key)
        if cached is None or self._snapshot_dir is None:
            return None
        return self._snapshot_dir / cached["file"], dict(cached)

    def _capture_save(self) -> tuple[list[_SaveItem], dict]:
        """Freeze everything a snapshot needs, on the calling thread.

        Cheap: shallow entry copies plus manifest/retention dicts — no
        array serialization, no hashing, no device sync.  After capture
        the snapshot content is fixed, so the write can proceed on a
        worker while the serving thread keeps mutating the live store.
        """
        items = [
            _SaveItem(
                key=key,
                entry=copy.copy(entry),
                source=self._entry_file_source(key, entry),
                manifest=self._entry_manifest(entry),
                retention={
                    "hits": entry.hits,
                    "created_s": entry.created_s,
                    "last_used_s": entry.last_used_s,
                },
            )
            for key, entry in self._entries().items()
        ]
        return items, self._store_meta()

    def _write_snapshot(self, root: Path, items: list[_SaveItem],
                        store_meta: dict) -> None:
        """Serialize captured items to ``root`` (temp dir + rename; see
        :meth:`save`).  Runs on the caller for sync saves and on the
        background writer for :meth:`save_async`."""
        root.parent.mkdir(parents=True, exist_ok=True)
        tmp = root.parent / f".{root.name}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        written = reused = 0
        new_records: dict[str, dict] = {}
        try:
            manifest: dict[str, Any] = {
                "version": MANIFEST_VERSION,
                "kind": type(self).__name__,
                "store": store_meta,
                "entries": [],
            }
            for i, item in enumerate(items):
                fname = f"entry_{i:06d}.npz"
                fpath = tmp / fname
                record = None
                if item.source is not None:
                    src, cached = item.source
                    try:
                        _link_or_copy(src, fpath)
                        record = cached
                        reused += 1
                    except OSError:
                        record = None  # source vanished: serialize fresh
                if record is None:
                    arrays, record = self._serialize_entry(item.entry)
                    # int8 payloads deflate well (and are off the serve
                    # latency path); fp32 entries keep the cheap raw write
                    if record.get("precision") == "int8":
                        np.savez_compressed(fpath, **arrays)
                    else:
                        np.savez(fpath, **arrays)
                    record["sha256"] = hashlib.sha256(
                        fpath.read_bytes()).hexdigest()
                    written += 1
                record["file"] = fname
                new_records[item.key] = dict(record)
                record.update(item.manifest)
                record["retention"] = item.retention
                manifest["entries"].append(record)
            (tmp / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1))
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if root.exists():
            old = root.parent / f".{root.name}.old-{os.getpid()}"
            if old.exists():
                shutil.rmtree(old)
            os.rename(root, old)
            os.rename(tmp, root)
        else:
            os.rename(tmp, root)
        # the snapshot at `root` is now complete: every `.old`/`.tmp`
        # sibling — this save's and any stranded by earlier crashed saves
        # (possibly other pids) — is stale; sweep them so crashes can't
        # leak full-size snapshot copies indefinitely
        for pattern in (f".{root.name}.old-*", f".{root.name}.tmp-*"):
            for stale in root.parent.glob(pattern):
                shutil.rmtree(stale, ignore_errors=True)
        # reused files were hard-linked, so sweeping the old snapshot dir
        # above cannot invalidate them — the inodes live on under `root`.
        # Entries replaced while this save was in flight must not get
        # their stale records installed.
        with self._records_lock:
            for k in self._records_dirty:
                new_records.pop(k, None)
            self._entry_records = new_records
        self._snapshot_dir = root
        self.last_save = {"written": written, "reused": reused}

    def save(self, path: str | Path) -> None:
        """Snapshot the store to ``path`` atomically and incrementally.

        Everything — per-entry ``entry_*.npz`` files and ``MANIFEST.json``
        — is written to a temporary sibling directory and renamed into
        place, so a crash mid-snapshot can never leave a half-written
        store behind: ``path`` either holds the previous complete snapshot
        or the new one.  Retention metadata (hits, created/last-used
        stamps) rides in the manifest; pins are runtime state and are
        deliberately not persisted.

        Saves are incremental over the previous snapshot: entries already
        present there are hard-linked (payloads are frozen at put time, so
        the bytes cannot have changed; filesystems without link support
        fall back to a copy) and only entries stored since are serialized,
        which makes frequent snapshotting (``--snapshot-every 1``) cost
        O(new entries) instead of O(store).  The manifest itself is always
        rewritten — mutable per-entry fields (:meth:`_entry_manifest`) and
        retention metadata stay fresh.  ``last_save`` records the
        ``{"written", "reused"}`` split.

        This is the synchronous form: any queued background saves are
        drained first, then the write runs on the calling thread.  See
        :meth:`save_async` for the non-blocking form.
        """
        self.flush_saves()
        with self._records_lock:
            self._records_dirty.clear()
        items, meta = self._capture_save()
        self._write_snapshot(Path(path), items, meta)

    def save_async(self, path: str | Path) -> bool:
        """Queue a snapshot of the store's *current* state on the
        background writer and return immediately.

        The snapshot content is captured on the calling thread (shallow
        entry copies — no serialization, no device sync), so later
        mutations don't bleed into it; the worker then runs the same
        atomic temp-dir+rename protocol as :meth:`save`, so a crash
        mid-write leaves the previous snapshot intact and the existing
        recovery paths apply unchanged.  At most one save is in flight per
        store: requests made while one is pending coalesce into nothing
        (counted in ``bg_save_drops`` — the next request snapshots
        everything anyway).  Returns ``True`` if the save was queued.
        Worker-side failures land in ``save_errors`` and never disturb the
        serving thread.
        """
        root = Path(path)
        with self._records_lock:
            if self._save_pending:
                self.bg_save_drops += 1
                return False
            self._save_pending = True
            self._records_dirty.clear()
        items, meta = self._capture_save()

        def _job() -> None:
            try:
                self._write_snapshot(root, items, meta)
                self.bg_saves += 1
            except BaseException as exc:
                self.save_errors.append(exc)
            finally:
                with self._records_lock:
                    self._save_pending = False

        if not self._ensure_writer().submit(_job):
            with self._records_lock:
                self._save_pending = False
            self.bg_save_drops += 1
            return False
        return True

    def flush_saves(self) -> float:
        """Block until every queued background write has landed; returns
        the seconds stalled (also accumulated in ``save_stall_s`` so the
        serving report can prove steady-state decode never waits here)."""
        if self._writer is None:
            return 0.0
        t0 = time.perf_counter()
        self._writer.drain()
        dt = time.perf_counter() - t0
        self.save_stall_s += dt
        return dt

    def compact_snapshot(self) -> Optional[dict]:
        """Rewrite this store's snapshot directory in place.

        Long-lived snapshot dirs accumulate cruft: hard-link chains shared
        with older generations and spill files (which keep dead inodes
        alive), and entry files stranded by crashed saves or compactions.
        Compaction rewrites the directory atomically (same temp-dir+rename
        protocol as :meth:`save`): manifest-listed entries are *copied* —
        never linked — into a compactly renumbered layout, so the rewritten
        snapshot holds the only reference to its bytes, and everything the
        manifest doesn't list is dropped.  Returns ``{"kept", "dropped"}``
        or ``None`` if the store has never been snapshotted.
        """
        if self._snapshot_dir is None:
            return None
        self.flush_saves()
        root = self._snapshot_dir
        stats = compact_snapshot_dir(root)
        # remap the incremental-save cache onto the renumbered files
        manifest = json.loads((root / MANIFEST_NAME).read_text())
        with self._records_lock:
            keep: dict[str, dict] = {}
            for rec in manifest["entries"]:
                key = rec.get("seg_id") or rec.get("model_id")
                if key in self._entry_records and key not in self._records_dirty:
                    keep[key] = {k: v for k, v in rec.items()
                                 if k != "retention"}
            self._entry_records = keep
        return stats

    @staticmethod
    def _recover_interrupted_swap(root: Path) -> None:
        """Heal the save swap's one non-atomic window.

        ``save`` renames the previous snapshot to ``.{name}.old-{pid}``
        before renaming the new one into place; a crash exactly between
        the two renames leaves ``root`` missing with the previous complete
        snapshot stranded under the ``.old`` name.  Load restores it, so
        the documented guarantee — ``path`` always yields a complete
        snapshot — holds across that window too.
        """
        if (root / MANIFEST_NAME).exists() or root.exists() \
                or not root.parent.exists():
            return
        for old in sorted(root.parent.glob(f".{root.name}.old-*")):
            if (old / MANIFEST_NAME).exists():
                os.rename(old, root)
                return

    @classmethod
    def load(cls, path: str | Path, *, verify: bool = True, **ctor_kwargs):
        """Rebuild a store from a :meth:`save` snapshot.

        ``ctor_kwargs`` are forwarded to the subclass constructor (byte
        budget, cost model, policy, …).  With ``verify`` (the default)
        every entry file's sha256 is checked against the manifest, so a
        corrupt or tampered snapshot raises instead of serving garbage.
        Retention metadata is restored per entry after insertion, so
        eviction resumes from honest hit counts and idle times.

        Entry files the manifest does not reference (stranded by a crashed
        compaction, or a foreign save interrupted after writing files but
        before its manifest) are ignored and swept — the manifest is the
        sole source of truth for what a snapshot contains.  The count
        lands in ``swept_stranded``.
        """
        root = Path(path)
        cls._recover_interrupted_swap(root)
        manifest = json.loads((root / MANIFEST_NAME).read_text())
        version = manifest.get("version")
        if version not in COMPAT_MANIFEST_VERSIONS:
            raise IOError(
                f"unsupported store manifest version {version!r} at {root} "
                f"(expected one of {COMPAT_MANIFEST_VERSIONS}); re-save the "
                f"store with the current code")
        store = cls(**ctor_kwargs)
        known = {rec["file"] for rec in manifest["entries"]}
        for stray in sorted(root.glob("entry_*.npz")):
            if stray.name not in known:
                stray.unlink()
                store.swept_stranded += 1
        meta = manifest.get("store", {})
        store._apply_store_meta(meta)
        for rec in manifest["entries"]:
            fpath = root / rec["file"]
            if verify:
                digest = hashlib.sha256(fpath.read_bytes()).hexdigest()
                if digest != rec["sha256"]:
                    raise IOError(f"checksum mismatch for {rec['file']}")
            arrays = np.load(fpath)
            store._load_src = fpath  # for hooks that park entries lazily
            key = store._deserialize_entry(rec, arrays)
            # a tighter budget than the snapshot's may evict entries while
            # they load; restore retention only for what stayed resident
            entry = store._entries().get(key)
            if entry is None:
                continue
            ret = rec.get("retention", {})
            entry.hits = int(ret.get("hits", entry.hits))
            entry.created_s = float(ret.get("created_s", entry.created_s))
            entry.last_used_s = float(ret.get("last_used_s",
                                              entry.last_used_s))
            # seed the incremental-snapshot cache: a load-then-save writes
            # nothing but the manifest (every entry file is reused).  The
            # record may carry stale mutable fields; save() re-merges
            # _entry_manifest over them.
            store._entry_records[key] = {
                k: v for k, v in rec.items() if k != "retention"}
        store._finish_load(meta)
        store._load_src = None
        store._snapshot_dir = root
        return store


#: historical name (the policy was global LRU through PR 2)
PinnedLRU = PinnedStore


def compact_snapshot_dir(path: str | Path) -> dict:
    """Atomically rewrite a snapshot directory to its minimal form.

    Keeps exactly the entry files the manifest references, renumbered
    compactly, each written as a private copy (``st_nlink == 1``) so
    hard-link chains to older snapshot generations and spill files are
    broken and deleting those actually frees bytes.  Files the manifest
    does not list — stranded by crashed saves or earlier compactions — are
    dropped, along with stale ``.old-*``/``.tmp-*`` siblings.  Safe on a
    snapshot mid-interrupted-swap (heals it first).  Returns
    ``{"kept": n, "dropped": m}``.
    """
    root = Path(path)
    PinnedStore._recover_interrupted_swap(root)
    manifest = json.loads((root / MANIFEST_NAME).read_text())
    tmp = root.parent / f".{root.name}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    kept = 0
    known: set[str] = set()
    try:
        for i, rec in enumerate(manifest["entries"]):
            src = root / rec["file"]
            known.add(rec["file"])
            fname = f"entry_{i:06d}.npz"
            # a full copy, never a link: compaction's whole point is that
            # the rewritten snapshot owns its bytes outright
            shutil.copy2(src, tmp / fname)
            rec["file"] = fname
            kept += 1
        (tmp / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1))
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    dropped = sum(1 for f in root.glob("entry_*.npz") if f.name not in known)
    old = root.parent / f".{root.name}.old-{os.getpid()}"
    if old.exists():
        shutil.rmtree(old)
    os.rename(root, old)
    os.rename(tmp, root)
    for pattern in (f".{root.name}.old-*", f".{root.name}.tmp-*"):
        for stale in root.parent.glob(pattern):
            shutil.rmtree(stale, ignore_errors=True)
    return {"kept": kept, "dropped": dropped}


class ModelStore(PinnedStore):
    """Per-family materialized models, indexed for Alg 3/4."""

    def __init__(self, byte_budget: Optional[int] = None, *,
                 cost_model: Optional[CostModel] = None,
                 policy: Optional[str] = None) -> None:
        super().__init__(cost_model=cost_model, policy=policy)
        self._models: dict[str, StoredModel] = {}
        self._indexes: dict[str, DescriptorIndex] = {}
        self._seq = 0
        self.byte_budget = byte_budget
        self.evictions = 0

    # -- crud --------------------------------------------------------------
    def put(self, family: str, rng: Range, stats: Combinable, meta: dict | None = None,
            model_id: str | None = None) -> str:
        if family not in STATS_FAMILIES:
            raise KeyError(f"unknown family {family!r}")
        if model_id is None:
            self._seq += 1
            model_id = f"{family}:{rng.lo}-{rng.hi}#{self._seq}"
        # replacing an id invalidates any snapshot file cached under it
        self._invalidate_record(model_id)
        sm = StoredModel(model_id=model_id, family=family, rng=rng,
                         stats=stats.to_numpy(), meta=meta or {})
        self._models[model_id] = sm
        self.index(family).add(model_id, rng)
        self._maybe_evict()
        return model_id

    def get(self, model_id: str) -> StoredModel:
        sm = self._models[model_id]
        sm.last_used_s = time.time()
        sm.hits += 1
        return sm

    def drop(self, model_id: str) -> None:
        sm = self._models.pop(model_id)
        self.index(sm.family).remove(model_id)

    def index(self, family: str) -> DescriptorIndex:
        if family not in self._indexes:
            self._indexes[family] = DescriptorIndex()
        return self._indexes[family]

    def models(self, family: str | None = None) -> Iterator[StoredModel]:
        for sm in self._models.values():
            if family is None or sm.family == family:
                yield sm

    def __len__(self) -> int:
        return len(self._models)

    # -- accounting ----------------------------------------------------------
    def nbytes(self, family: str | None = None) -> int:
        return sum(sm.nbytes for sm in self.models(family))

    def model_bytes(self, family: str) -> dict[str, int]:
        return {sm.model_id: sm.nbytes for sm in self.models(family)}

    def coverage(self, family: str, universe: Range) -> float:
        return self.index(family).coverage(universe)

    def _entries(self) -> dict:
        return self._models

    def _evict(self, victim: StoredModel) -> None:
        self.drop(victim.model_id)

    # -- persistence (PinnedStore hooks) ---------------------------------------
    def _serialize_entry(self, sm: StoredModel) -> tuple[dict, dict]:
        import jax

        leaves, _ = jax.tree_util.tree_flatten(sm.stats)
        arrays = {f"leaf_{j}": np.asarray(x) for j, x in enumerate(leaves)}
        record = {
            "model_id": sm.model_id,
            "family": sm.family,
            "lo": sm.rng.lo,
            "hi": sm.rng.hi,
            "n_leaves": len(leaves),
        }
        return arrays, record

    def _entry_manifest(self, sm: StoredModel) -> dict:
        # meta may be amended after the put; keep it out of the cached
        # immutable record so incremental saves never persist a stale copy
        return {"meta": sm.meta}

    def _deserialize_entry(self, rec: dict, arrays) -> str:
        import dataclasses as dc

        leaves = [arrays[f"leaf_{j}"] for j in range(rec["n_leaves"])]
        proto = STATS_FAMILIES[rec["family"]]
        # rebuild via the dataclass fields of the family's stats type
        fields = [f.name for f in dc.fields(proto)]
        stats = proto(**dict(zip(fields, leaves)))
        return self.put(rec["family"], Range(rec["lo"], rec["hi"]), stats,
                        meta=rec.get("meta", {}), model_id=rec["model_id"])

    @classmethod
    def load(cls, path: str | Path, byte_budget: Optional[int] = None,
             verify: bool = True) -> "ModelStore":
        return super().load(path, verify=verify, byte_budget=byte_budget)
