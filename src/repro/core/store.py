"""Materialized-model store: descriptors + sufficient statistics + persistence.

Storage cost is the paper's explicit trade-off (Table 1) — the store tracks
bytes per family and supports an LRU byte budget.  Persistence is a plain
``npz`` per model plus a JSON manifest so a store survives process restarts
(and, at cluster scale, host replacement: the manifest carries content
hashes for integrity).
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Iterator, Optional

import numpy as np

from .descriptors import DescriptorIndex, Range
from .suffstats import STATS_FAMILIES, Combinable


@dataclass
class StoredModel:
    model_id: str
    family: str
    rng: Range
    stats: Combinable
    created_s: float = field(default_factory=time.time)
    last_used_s: float = field(default_factory=time.time)
    meta: dict = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return self.stats.nbytes


class ModelStore:
    """Per-family materialized models, indexed for Alg 3/4."""

    def __init__(self, byte_budget: Optional[int] = None) -> None:
        self._models: dict[str, StoredModel] = {}
        self._indexes: dict[str, DescriptorIndex] = {}
        self._seq = 0
        self.byte_budget = byte_budget
        self.evictions = 0

    # -- crud --------------------------------------------------------------
    def put(self, family: str, rng: Range, stats: Combinable, meta: dict | None = None,
            model_id: str | None = None) -> str:
        if family not in STATS_FAMILIES:
            raise KeyError(f"unknown family {family!r}")
        if model_id is None:
            self._seq += 1
            model_id = f"{family}:{rng.lo}-{rng.hi}#{self._seq}"
        sm = StoredModel(model_id=model_id, family=family, rng=rng,
                         stats=stats.to_numpy(), meta=meta or {})
        self._models[model_id] = sm
        self.index(family).add(model_id, rng)
        self._maybe_evict()
        return model_id

    def get(self, model_id: str) -> StoredModel:
        sm = self._models[model_id]
        sm.last_used_s = time.time()
        return sm

    def drop(self, model_id: str) -> None:
        sm = self._models.pop(model_id)
        self.index(sm.family).remove(model_id)

    def index(self, family: str) -> DescriptorIndex:
        if family not in self._indexes:
            self._indexes[family] = DescriptorIndex()
        return self._indexes[family]

    def models(self, family: str | None = None) -> Iterator[StoredModel]:
        for sm in self._models.values():
            if family is None or sm.family == family:
                yield sm

    def __len__(self) -> int:
        return len(self._models)

    # -- accounting ----------------------------------------------------------
    def nbytes(self, family: str | None = None) -> int:
        return sum(sm.nbytes for sm in self.models(family))

    def model_bytes(self, family: str) -> dict[str, int]:
        return {sm.model_id: sm.nbytes for sm in self.models(family)}

    def coverage(self, family: str, universe: Range) -> float:
        return self.index(family).coverage(universe)

    def _maybe_evict(self) -> None:
        if self.byte_budget is None:
            return
        while self.nbytes() > self.byte_budget and len(self._models) > 1:
            victim = min(self._models.values(), key=lambda sm: sm.last_used_s)
            self.drop(victim.model_id)
            self.evictions += 1

    # -- persistence -----------------------------------------------------------
    def save(self, path: str | Path) -> None:
        root = Path(path)
        root.mkdir(parents=True, exist_ok=True)
        manifest: dict[str, Any] = {"version": 1, "models": []}
        for i, sm in enumerate(self._models.values()):
            import jax

            leaves, treedef = jax.tree_util.tree_flatten(sm.stats)
            fname = f"model_{i:06d}.npz"
            arrays = {f"leaf_{j}": np.asarray(x) for j, x in enumerate(leaves)}
            fpath = root / fname
            np.savez(fpath, **arrays)
            digest = hashlib.sha256(fpath.read_bytes()).hexdigest()
            manifest["models"].append(
                {
                    "model_id": sm.model_id,
                    "family": sm.family,
                    "lo": sm.rng.lo,
                    "hi": sm.rng.hi,
                    "file": fname,
                    "sha256": digest,
                    "n_leaves": len(leaves),
                    "meta": sm.meta,
                }
            )
        (root / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))

    @classmethod
    def load(cls, path: str | Path, byte_budget: Optional[int] = None,
             verify: bool = True) -> "ModelStore":
        import jax

        root = Path(path)
        manifest = json.loads((root / "MANIFEST.json").read_text())
        store = cls(byte_budget=byte_budget)
        for ent in manifest["models"]:
            fpath = root / ent["file"]
            if verify:
                digest = hashlib.sha256(fpath.read_bytes()).hexdigest()
                if digest != ent["sha256"]:
                    raise IOError(f"checksum mismatch for {ent['file']}")
            data = np.load(fpath)
            leaves = [data[f"leaf_{j}"] for j in range(ent["n_leaves"])]
            proto = STATS_FAMILIES[ent["family"]]
            # rebuild via treedef of a zero instance with matching structure
            import dataclasses as dc

            fields = [f.name for f in dc.fields(proto)]
            stats = proto(**dict(zip(fields, leaves)))
            store.put(ent["family"], Range(ent["lo"], ent["hi"]), stats,
                      meta=ent.get("meta", {}), model_id=ent["model_id"])
        return store
