"""Serve a small model with batched requests, reusing KV-cache segments via
the paper's descriptor planner (the inference instance of incremental model
reuse).

    PYTHONPATH=src python examples/serve_prefix_reuse.py
"""
import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models.lm import LM
from repro.serve.engine import ServeEngine

cfg = reduced(ARCHS["deepseek-67b"])
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
doc = rng.integers(0, cfg.vocab_size, 2048).astype(np.int32)  # shared context

eng = ServeEngine(model, params, doc, chunk_tokens=128)

requests = [(512, 8), (1024, 8), (768, 8), (2000, 8), (1024, 8)]
for i, (prefix, n_new) in enumerate(requests):
    toks, plan = eng.generate(prefix, n_new, greedy=False, seed=i)
    print(f"request {i}: prefix={prefix:5d}  cached-segments used "
          f"{len(plan.models_used):2d}  generated {toks}")

s = eng.stats
print(f"\nreuse fraction {s.reuse_frac:.1%}  "
      f"({s.tokens_reused} tokens reused, {s.tokens_computed} computed)")
print(f"planner total {s.planner_s*1e3:.1f} ms — negligible vs prefill "
      f"{s.prefill_s:.2f}s (the paper's §6.4 result, at serving time)")
assert s.reuse_frac > 0.3
