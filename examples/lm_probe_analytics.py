"""Incremental analytics over LM activations: train a small backbone a few
hundred steps, then fit ridge-regression probes over hidden-state ranges
with materialization + reuse — the paper's technique as a first-class
feature of the LM stack.

    PYTHONPATH=src python examples/lm_probe_analytics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import IncrementalAnalyticsEngine, Range
from repro.data import ArrayBackend
from repro.data.pipeline import lm_pipeline
from repro.models.lm import LM
from repro.train.loop import train_loop
from repro.train.optim import warmup_cosine

# 1) train a reduced backbone for a few hundred steps
cfg = reduced(ARCHS["qwen3-32b"]).replace(train_microbatches=2)
model = LM(cfg)
pipe = lm_pipeline(cfg.vocab_size, batch=8, seq=64, n_shards=2, seed=0)
batches = ({k: jnp.asarray(v) for k, v in b.items()} for b in pipe)
state, hist = train_loop(model, batches, steps=300,
                         schedule=warmup_cosine(3e-3, 20, 300))
pipe.close()
print(f"backbone: loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f} over 300 steps")

# 2) stream activations over an ordered token corpus
from repro.data.tokens import TokenStream

stream = TokenStream(cfg.vocab_size, seed=7)
feats, targs = [], []
fwd = jax.jit(lambda p, b: model.forward(p, b, remat=False)[0])
for step in range(40):
    b = stream.batch(0, step, 4, 64)
    h = fwd(state.params, {"tokens": jnp.asarray(b["tokens"])})
    feats.append(np.asarray(h, np.float64).reshape(-1, cfg.d_model))
    # probe target: a deterministic property of the current token — linearly
    # decodable from the hidden state, so the probe has signal to find
    targs.append(((b["tokens"] % 7) / 7.0).astype(np.float64).reshape(-1))
X = np.concatenate(feats)   # ordered by token position → valid descriptors
y = np.concatenate(targs)
print(f"activation stream: {X.shape[0]} ordered feature rows of dim {X.shape[1]}")

# 3) incremental probe analytics over activation ranges
eng = IncrementalAnalyticsEngine(ArrayBackend(X, y), materialize="always")
n = len(y)
r1 = eng.query("linreg", Range(0, n // 2))
r2 = eng.query("linreg", Range(0, n))          # reuses first-half stats
r3 = eng.query("linreg", Range(n // 4, n // 2))  # derived by subtraction
print(f"probe R² first-half={r1.model.r2(X[:n//2], y[:n//2]):.3f}  "
      f"full={r2.model.r2(X, y):.3f}")
print(f"full-range probe scanned only {r2.plan.base_points}/{n} rows; "
      f"drill-down scanned {r3.plan.base_points}")
assert r2.plan.base_points <= n // 2 + 1
print("incremental probe reuse ✓")
