"""End-to-end driver: replay an exploratory-analysis workload (the paper's
own scenario, §1/§6) over all three model families and report speedups,
then persist & reload the materialized-model store.

    PYTHONPATH=src python examples/analytics_workload.py
"""
import tempfile
import time

import numpy as np

from repro.core import IncrementalAnalyticsEngine, ModelStore, Range
from repro.data import ArrayBackend, make_classification, make_regression

N, D = 400_000, 10
rng = np.random.default_rng(0)

Xr, yr = make_regression(N, d=D, seed=0)
Xc, yc = make_classification(N, d=D, n_classes=2, seed=1)

workload = []
# a realistic exploratory session: build-then-refine ranges
cursor = 0
while cursor < N - 60_000:
    size = int(rng.integers(20_000, 60_000))
    workload.append(Range(cursor, cursor + size))               # build
    workload.append(Range(cursor, cursor + size + 20_000))      # extend
    workload.append(Range(cursor + size // 3, cursor + size))   # drill down
    cursor += size

for family, backend in (
    ("linreg", ArrayBackend(Xr, yr)),
    ("gaussian_nb", ArrayBackend(Xc, yc)),
    ("logreg", ArrayBackend(Xc, yc)),
):
    params = {"chunk_size": 10_000} if family == "logreg" else {}
    eng = IncrementalAnalyticsEngine(
        backend, materialize="chunks" if family == "logreg" else "always")
    t_ours = t_base = 0.0
    for q in workload:
        t0 = time.perf_counter(); eng.query(family, q, **params); t_ours += time.perf_counter() - t0
        t0 = time.perf_counter(); eng.baseline(family, q, **params); t_base += time.perf_counter() - t0
    print(f"{family:14s}: {len(workload)} queries  "
          f"workload speedup {t_base/t_ours:.2f}x  "
          f"coverage {eng.coverage(family):.0%}  "
          f"store {eng.store.nbytes()/1e6:.2f} MB "
          f"({eng.store.nbytes()/(Xr.nbytes+yr.nbytes):.2%} of base)")

    # persistence: the store survives restarts (and host replacement)
    with tempfile.TemporaryDirectory() as d:
        eng.store.save(d)
        loaded = ModelStore.load(d)
        assert len(loaded) == len(eng.store)
print("store persistence round-trip ✓")
