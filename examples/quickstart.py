"""Quickstart: model materialization + incremental reuse in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IncrementalAnalyticsEngine, Range, linreg
from repro.data import ArrayBackend, RemoteStoreBackend, make_regression

# an ordered data set (ids 0..N) behind disaggregated storage:
# e.g. a month of telemetry in a remote columnar store
X, y = make_regression(200_000, d=10, seed=0)
backend = RemoteStoreBackend(ArrayBackend(X, y))
engine = IncrementalAnalyticsEngine(backend, materialize="always")

# week 1+2 model — built from raw data, then materialized
r1 = engine.query("linreg", Range(0, 100_000))
print(f"weeks 1-2: scanned {r1.plan.base_points} points, "
      f"R²={r1.model.r2(X[:100_000], y[:100_000]):.3f}")

# whole-month model — the planner reuses the materialized weeks-1-2 stats
r2 = engine.query("linreg", Range(0, 200_000))
print(f"month:     scanned {r2.plan.base_points} points "
      f"(reused {[s.model_id for s in r2.plan.steps if s.model_id]})")

# drill-down past a bad first day — derived by *subtracting* statistics:
# fetch only the 10K-point complement instead of scanning 90K points
r3 = engine.query("linreg", Range(10_000, 100_000))
print(f"drill-down: scanned {r3.plan.base_points} points, "
      f"plan={[(str(s.rng), s.sign) for s in r3.plan.steps]}")

# identical to building from scratch (the paper's exactness guarantee)
direct = linreg.fit(X[10_000:100_000], y[10_000:100_000])
assert np.allclose(r3.model.weights, direct.weights, rtol=1e-7)
assert r3.plan.base_points < 90_000
print("drill-down weights match from-scratch fit ✓")
